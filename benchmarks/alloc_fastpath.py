"""alloc_fastpath — jitted planner core + free replans (ISSUE 7 / DESIGN.md §11).

Three measurements on a G=6 heterogeneous fleet:

1. **Planner fast path** — wall-clock of ``AllocationScheme.allocate``
   with the jitted Theorem-2/Lambert-W core (``core/alloc_fastpath``)
   vs the eager numpy oracle (``allocation.eager_oracle()``), memo cache
   cleared between calls so every rep pays a full solve. Gate: the
   jitted path is at least ``SPEEDUP_GATE``x faster on both the
   closed-form (``optimal``) and bisection-heavy (``comm_aware``)
   schemes.
2. **Zero-retrace replans** — a bucket-mode ``CodedRoundExecutor``
   driven by an ``AdaptiveController`` at ``every=1`` over a mu-drift
   sequence, with a compiled bucket-switch probe program: every replan
   lands as an in-program bucket switch and the trace counter stays
   pinned at 1 (the "free replan": plan changed, nothing recompiled).
3. **fig_adapt cadence comparison** — ``run_scenario`` at ``every=1``
   with bucketing (replan cost charged only on true bucket misses) must
   be no slower than the default ``every=5`` cadence on the drift
   scenarios: replans being free makes the tightest cadence affordable.

``--reduced`` (the CI fast lane) shortens the horizons and ASSERTS all
three gates.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, table
from repro.core import allocation
from repro.core.runtime_model import ClusterSpec
from repro.core.schemes import (
    allocate_cache_clear,
    allocate_cache_info,
    make_scheme,
)
from repro.runtime.compile_cache import cache_dir
from repro.runtime.control import AdaptConfig, AdaptiveController
from repro.runtime.executor import CodedRoundExecutor
from repro.runtime.plan_bucket import BucketConfig
from repro.runtime.telemetry import Telemetry

K = 2_000  # coded rows / partitions
SPEEDUP_GATE = 50.0  # jitted allocate must beat eager by at least this
#: G=6 heterogeneous fleet behind finite links (comm_aware needs
#: bandwidths); spans 32x in mu and 16x in bandwidth
G6 = ClusterSpec.make(
    [8, 16, 8, 4, 6, 10],
    [4.0, 1.0, 0.25, 2.0, 0.5, 8.0],
    1.0,
    [16.0, 8.0, 4.0, 2.0, 8.0, 32.0],
)
#: schemes the jitted core rewrites: the closed-form Theorem-2 path and
#: the bisection-heavy comm-aware path (eq. (28) + (26))
TIMED_SCHEMES = ("optimal", "comm_aware")
#: drift scenarios for the cadence comparison (stable membership, so
#: every replan is bucket-eligible)
DRIFT_SCENARIOS = ("mu_drift", "mu_step")
#: bucket quantum for the replan demos: coarse enough that the ~2%
#: estimate wobble of the closed-loop tracker maps repeat visits onto
#: the SAME quantized signature (bucket hits, i.e. free replans)
DEMO_QUANTUM = 16


def _time_allocate(scheme, *, fastpath: bool, reps: int) -> float:
    """Median seconds per full ``allocate`` solve (memo cleared each rep)."""
    times = []
    for _ in range(reps):
        allocate_cache_clear()
        t0 = time.perf_counter()
        if fastpath:
            scheme.allocate(G6, K)
        else:
            with allocation.eager_oracle():
                scheme.allocate(G6, K)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def timing_rows(*, fast_reps: int, eager_reps: int) -> list[dict]:
    rows = []
    for name in TIMED_SCHEMES:
        scheme = make_scheme(name)
        # warm both paths once: the jitted core's compile (amortized by
        # the persistent compilation cache across processes) and numpy's
        # first-touch overheads are not the steady-state replan cost
        allocate_cache_clear()
        scheme.allocate(G6, K)
        allocate_cache_clear()
        with allocation.eager_oracle():
            scheme.allocate(G6, K)
        fast = _time_allocate(scheme, fastpath=True, reps=fast_reps)
        eager = _time_allocate(scheme, fastpath=False, reps=eager_reps)
        rows.append({
            "scheme": name,
            "eager_ms": eager * 1e3,
            "fast_ms": fast * 1e3,
            "speedup": eager / fast,
        })
    return rows


def replan_trace_demo(*, rounds: int = 18) -> dict:
    """Adaptive replans at every=1 under a mu step: trace count stays 1.

    The compiled probe program is a stand-in for the fused serve/train
    step: it consumes ``bucket_args()`` as runtime arguments and selects
    the active branch in-program, exactly like ``Server._coded_select``
    and the coded train step do. The truth steps the big middle group
    3x faster for the middle third of the run, then reverts — the
    controller replans out and back (the return trip is a bucket HIT),
    and the probe never retraces.
    """
    telemetry = Telemetry(None)
    exe = CodedRoundExecutor(
        G6, K, "optimal", bucket_config=BucketConfig(quantum=DEMO_QUANTUM),
        telemetry=telemetry,
    )
    traces = {"n": 0}

    def probe(key, state, index):
        traces["n"] += 1  # python side effect: runs only while tracing
        mask, sel = exe.finish_mask_bucket_jit(key, state, index)
        return jnp.sum(exe.slot_mask_bucket_jit(mask, sel))

    step = jax.jit(probe)
    ctl = AdaptiveController(
        exe,
        AdaptConfig(every=1, threshold=0.0, replan_cost=0.05),
        telemetry=telemetry,
    )
    key = jax.random.PRNGKey(7)
    replans = 0
    for t in range(rounds):
        f = 3.0 if rounds // 3 <= t < 2 * rounds // 3 else 1.0
        g1 = dataclasses.replace(G6.groups[1], mu=G6.groups[1].mu * f)
        truth = ClusterSpec(groups=(G6.groups[0], g1) + G6.groups[2:])
        step(jax.random.fold_in(key, t), *exe.bucket_args())
        d = ctl.observe_truth(jax.random.fold_in(key, t), truth)
        if d is not None and d.replanned:
            replans += 1
    events = [
        e for e in telemetry.events
        if e.get("event", "").startswith("plan_bucket")
    ]
    return {
        "rounds": rounds,
        "replans": replans,
        "traces": traces["n"],
        "buckets": len(exe.buckets),
        "bucket_hits": sum(
            1 for e in events if e["event"] == "plan_bucket_hit"
        ),
        "bucket_misses": sum(
            1 for e in events if e["event"] == "plan_bucket_miss"
        ),
        "structural_misses": sum(
            1 for e in events
            if e["event"] == "plan_bucket_miss" and e["structural"]
        ),
    }


def adapt_comparison(*, horizon: int | None) -> list[dict]:
    """fig_adapt drift scenarios: every=1 bucketed vs the every=5 default."""
    from benchmarks.fig_adapt import run_scenario

    rows = []
    for name in DRIFT_SCENARIOS:
        r5 = run_scenario(name, horizon=horizon)
        r1 = run_scenario(name, horizon=horizon, every=1,
                          bucket_quantum=DEMO_QUANTUM)
        rows.append({
            "scenario": name,
            "adaptive_e5": r5["adaptive"],
            "adaptive_e1_bucket": r1["adaptive"],
            "ratio": r1["adaptive"] / r5["adaptive"],
            "replans_e1": r1["replans"],
            "free_replans_e1": r1["free_replans"],
        })
    return rows


def run(verbose: bool = True, *, reduced: bool = False) -> dict:
    fast_reps = 7 if reduced else 21
    eager_reps = 2 if reduced else 3
    rows = timing_rows(fast_reps=fast_reps, eager_reps=eager_reps)
    demo = replan_trace_demo()
    adapt_rows = adapt_comparison(horizon=48 if reduced else None)
    record = {
        "k": K,
        "cluster": [
            (g.num_workers, g.mu, g.bandwidth) for g in G6.groups
        ],
        "speedup_gate": SPEEDUP_GATE,
        "timing": rows,
        "min_speedup": min(r["speedup"] for r in rows),
        "replan_demo": demo,
        "adapt_rows": adapt_rows,
        "max_adapt_ratio": max(r["ratio"] for r in adapt_rows),
        "alloc_cache": allocate_cache_info(),
        "compile_cache_dir": cache_dir(),
    }
    if verbose:
        print(f"alloc_fastpath: jitted allocate vs eager oracle "
              f"(G={G6.num_groups}, k={K})")
        print(table(rows, ["scheme", "eager_ms", "fast_ms", "speedup"]))
        print(f"min speedup {record['min_speedup']:.0f}x "
              f"(gate {SPEEDUP_GATE:.0f}x)")
        print(f"replan demo: {demo['replans']} replans in {demo['rounds']} "
              f"rounds -> {demo['traces']} trace(s), "
              f"{demo['bucket_hits']} bucket hits / "
              f"{demo['bucket_misses']} misses "
              f"({demo['buckets']} buckets admitted)")
        print(table(adapt_rows, ["scenario", "adaptive_e5",
                                 "adaptive_e1_bucket", "ratio",
                                 "replans_e1", "free_replans_e1"]))
    save("alloc_fastpath", record)
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduced", action="store_true",
                    help="CI smoke: fewer reps + short horizons, gates "
                         "ASSERTED")
    args = ap.parse_args()
    rec = run(reduced=args.reduced)
    if args.reduced:
        assert rec["min_speedup"] >= SPEEDUP_GATE, rec["timing"]
        assert rec["replan_demo"]["traces"] == 1, rec["replan_demo"]
        assert rec["replan_demo"]["structural_misses"] == 0, rec["replan_demo"]
        assert rec["max_adapt_ratio"] <= 1.02, rec["adapt_rows"]
